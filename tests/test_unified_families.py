"""The ONE ragged step path (PR 3 acceptance): every model family runs
chunked continuation prefill through the same engine/scheduler composition —
greedy parity chunked-vs-whole-prompt, prefix-cache reuse on repeated
prompts, preemption with token-identical greedy resume, recurrent state
threaded across chunks, and fair mixed-step timing attribution.

All output comparisons run greedy in ORIGINAL (bf16) mode so schedule
differences can only surface as genuine numeric differences.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.coopt import COOPT, MODES, ORIGINAL
from repro.serving import Engine, EngineConfig, Request
from repro.serving.request import RequestState

FAMILIES = ["qwen3-4b", "deepseek-v2-lite-16b", "internvl2-2b",
            "whisper-small", "rwkv6-7b", "recurrentgemma-9b"]
RECURRENT = ["rwkv6-7b", "recurrentgemma-9b"]


def _cfg(arch):
    return get_config(arch + "-reduced")


def _prompt(cfg, n, seed=0):
    return np.random.default_rng(seed).integers(0, cfg.vocab_size, n,
                                                dtype=np.int32)


# ---------------------------------------------------------------- parity --
@pytest.mark.parametrize("arch", FAMILIES)
def test_chunked_vs_whole_prompt_greedy_parity(arch):
    """Small buckets force multi-chunk prefill; big buckets serve the whole
    prompt in one chunk. Both run the SAME continuation path over the same
    cached bytes, so greedy outputs are identical."""
    cfg = _cfg(arch)
    prompt = _prompt(cfg, 100, seed=1)
    outs = []
    for buckets in ((16, 32), (64, 128, 256)):
        eng = Engine(cfg, ORIGINAL,
                     EngineConfig(num_lanes=2, max_len=256,
                                  prefill_buckets=buckets))
        outs.append(eng.generate([prompt], max_new_tokens=8)[0])
        assert len(outs[-1]) == 8
    assert outs[0] == outs[1]


# ---------------------------------------------------------------- prefix --
@pytest.mark.parametrize("arch", FAMILIES)
def test_prefix_cache_hits_on_repeated_prompt(arch):
    """A repeated prompt (>= 1 full page) prefix-hits for EVERY family —
    attention families reuse KV/latent pages; recurrent families also
    restore the page-boundary state snapshot — with identical greedy
    output warm vs cold."""
    cfg = _cfg(arch)
    prompt = _prompt(cfg, 100, seed=2)                # > page_size 64
    eng = Engine(cfg, ORIGINAL,
                 EngineConfig(num_lanes=2, max_len=256,
                              prefill_buckets=(16, 32, 64, 128)))
    cold = eng.generate([prompt], max_new_tokens=4)[0]
    warm = eng.generate([prompt], max_new_tokens=4)[0]
    assert eng.stats.prefix_cache_hits > 0
    assert cold == warm


# ------------------------------------------------------------ preemption --
@pytest.mark.parametrize("arch", FAMILIES)
def test_preempt_and_resume_token_identical(arch):
    """An over-subscribed pool completes via preemption with outputs
    identical to an unconstrained run — uniformly, including the families
    that used to run the monolithic tier."""
    cfg = _cfg(arch)
    # admit on one page each, collide on the shared 3rd page during decode
    # growth (vlm's 16-position patch stub counts against its page)
    plen = 44 if cfg.family == "vlm" else 50
    prompts = [_prompt(cfg, plen, seed=3 + i) for i in range(2)]
    tight = EngineConfig(num_lanes=2, max_len=128,
                         prefill_buckets=(16, 32, 64, 128))
    roomy = EngineConfig(num_lanes=2, max_len=256,
                         prefill_buckets=(16, 32, 64, 128, 256))
    eng_t = Engine(cfg, ORIGINAL, tight)
    out_t = eng_t.generate(prompts, max_new_tokens=20)
    eng_r = Engine(cfg, ORIGINAL, roomy)
    out_r = eng_r.generate(prompts, max_new_tokens=20)
    assert eng_t.stats.preemptions > 0
    assert eng_r.stats.preemptions == 0
    assert all(len(o) == 20 for o in out_t)
    assert out_t == out_r


# ---------------------------------------------------- recurrent regression --
@pytest.mark.parametrize("arch", RECURRENT)
def test_recurrent_state_threads_across_chunks(arch):
    """Model-level regression: feeding a prompt as N continuation chunks
    (state after chunk k = input state of chunk k+1) matches the monolithic
    single-call prefill — final logits and recurrent state agree."""
    import jax
    import jax.numpy as jnp
    from repro.core.opt_kv import identity_slots
    from repro.models import get_model

    cfg = _cfg(arch)
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S, C = 2, 48, 16
    coopt = ORIGINAL
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)

    mono_cache = m.init_cache(B, S + 16, coopt)
    mono_logits, mono_cache = m.prefill(p, {"tokens": toks}, mono_cache,
                                        coopt)

    ch_cache = m.init_cache(B, S + 16, coopt)
    P_total = (ch_cache["kv"].shape[2] if "kv" in ch_cache
               else 1)                                   # rwkv6: no pool
    for i in range(0, S, C):
        pos = jnp.broadcast_to(jnp.arange(i, i + C), (B, C)).astype(jnp.int32)
        slots = identity_slots(B, pos, P_total, coopt.page_size)
        ch_logits, ch_cache = m.prefill(
            p, {"tokens": toks[:, i:i + C], "positions": pos,
                "slot_idx": slots,
                "cache_len": jnp.full((B,), i + C, jnp.int32)},
            ch_cache, coopt)

    a = np.asarray(mono_logits, np.float32)
    b = np.asarray(ch_logits, np.float32)
    atol = 0.05 * max(np.abs(a).max(), 1.0)
    np.testing.assert_allclose(a, b, atol=atol)
    # the carried state itself must agree, not just the logits
    for leaf in m.recurrent_leaves:
        x = np.asarray(mono_cache[leaf], np.float32)
        y = np.asarray(ch_cache[leaf], np.float32)
        np.testing.assert_allclose(
            x, y, atol=0.05 * max(np.abs(x).max(), 1.0),
            err_msg=f"{arch} state leaf {leaf} diverged across chunks")


@pytest.mark.parametrize("arch", RECURRENT)
def test_recurrent_lane_reuse_does_not_leak_state(arch):
    """A request admitted on a lane previously used by another request must
    see ZERO initial state, not the previous occupant's — its output equals
    a fresh-engine run of the same prompt."""
    cfg = _cfg(arch)
    ecfg = EngineConfig(num_lanes=1, max_len=256,
                        prefill_buckets=(16, 32, 64),
                        enable_prefix_cache=False)
    p1, p2 = _prompt(cfg, 40, seed=7), _prompt(cfg, 40, seed=8)
    eng = Engine(cfg, ORIGINAL, ecfg)
    eng.generate([p1], max_new_tokens=4)                # dirties lane 0
    reused = eng.generate([p2], max_new_tokens=4)[0]
    fresh = Engine(cfg, ORIGINAL, ecfg).generate([p2], max_new_tokens=4)[0]
    assert reused == fresh


@pytest.mark.parametrize("arch", RECURRENT)
def test_recurrent_prefix_hit_with_multi_page_chunk(arch):
    """Regression: a prompt prefilled as ONE multi-page chunk snapshots
    state only at the chunk-end boundary; matching must TRIM to that
    boundary (deepest gated hash), not break at the first page whose hash
    lacks a snapshot — which yielded zero hits."""
    cfg = _cfg(arch)
    prompt = _prompt(cfg, 200, seed=11)             # 3 full pages + tail
    eng = Engine(cfg, ORIGINAL,
                 EngineConfig(num_lanes=2, max_len=256,
                              prefill_buckets=(64, 128, 256)))
    cold = eng.generate([prompt], max_new_tokens=4)[0]
    warm = eng.generate([prompt], max_new_tokens=4)[0]
    assert eng.stats.prefix_cache_hits >= 3         # all 3 full pages reused
    assert cold == warm


def test_long_window_decode_schedule_independent():
    """Regression: with ``long_window`` set, a decode token must get the
    same {sink + sliding window} policy whether its step is decode-only or
    shares the device call with another request's prefill chunks."""
    cfg = _cfg("qwen3-4b")
    ecfg = EngineConfig(num_lanes=2, max_len=256,
                        prefill_buckets=(16, 32, 64, 128), long_window=32)
    r1 = _prompt(cfg, 120, seed=12)
    r2 = _prompt(cfg, 100, seed=13)

    eng_solo = Engine(cfg, ORIGINAL, ecfg)
    solo = eng_solo.generate([r1], max_new_tokens=10)[0]

    eng_mix = Engine(cfg, ORIGINAL, ecfg)
    req1 = Request(req_id=1, prompt=r1, max_new_tokens=10)
    eng_mix.add_request(req1)
    for _ in range(6):                              # r1 reaches decode
        eng_mix.step()
    eng_mix.add_request(Request(req_id=2, prompt=r2, max_new_tokens=10))
    eng_mix.run()                                   # r1 decodes in MIXED steps
    assert eng_mix.stats.mixed_steps > 0
    assert req1.output == solo


# ------------------------------------------------------- timing / latency --
def test_mixed_step_timing_attribution_and_latency_metrics():
    """Mixed-step wall time splits by planned token share: a prefill-only
    run books nothing under decode_time, and a decode-bearing run books
    both. Per-request TTFT/TPOT percentiles populate from finished
    requests."""
    cfg = _cfg("qwen3-4b")
    ecfg = EngineConfig(num_lanes=2, max_len=128,
                        prefill_buckets=(16, 32, 64))
    eng = Engine(cfg, MODES["coopt"], ecfg)
    prompts = [_prompt(cfg, 40, seed=9), _prompt(cfg, 30, seed=10)]

    reqs = eng.generate(prompts, max_new_tokens=1, return_requests=True)
    assert all(r.state is RequestState.FINISHED for r in reqs)
    assert eng.stats.prefill_time > 0
    assert eng.stats.decode_time == 0                  # no decode tokens ran
    assert len(eng.stats.ttft_s) == 2
    assert all(t > 0 for t in eng.stats.ttft_s)
    assert eng.stats.tpot_s == []                      # 1 token: no TPOT

    out = eng.generate(prompts, max_new_tokens=8)
    assert all(len(o) == 8 for o in out)
    assert eng.stats.decode_time > 0
    assert eng.stats.prefill_time > 0
    assert len(eng.stats.tpot_s) == 2
    summ = eng.stats.latency_summary()
    assert summ["ttft_p95_s"] >= summ["ttft_p50_s"] > 0
    assert summ["tpot_p95_s"] >= summ["tpot_p50_s"] > 0


# ------------------------------------------------- MLA fused latent path --
@pytest.mark.parametrize("mode", ["coopt", "original"])
def test_mla_engine_use_kernel_greedy_identical(mode):
    """End-to-end MLA serving through the fused latent Pallas kernels
    (absorbed decode + chunk prefill straight off the paged latent pool)
    must be greedy-identical to the jnp parity reference — fp8 (coopt) and
    bf16 (original), across multi-chunk prefill, prefix reuse and decode."""
    cfg = _cfg("deepseek-v2-lite-16b")
    prompts = [_prompt(cfg, 100, seed=21), _prompt(cfg, 45, seed=22)]
    outs = []
    for uk in (False, True):
        eng = Engine(cfg, MODES[mode].replace(use_kernel=uk),
                     EngineConfig(num_lanes=2, max_len=256,
                                  prefill_buckets=(16, 32, 64, 128)))
        outs.append(eng.generate(prompts, max_new_tokens=8))
        assert all(len(o) == 8 for o in outs[-1])
    assert outs[0] == outs[1]


def test_mla_engine_use_kernel_windowed_greedy_identical():
    """The windowed latent-kernel variant ({sink + sliding window}
    block-sparse policy) matches the jnp reference through the engine."""
    cfg = _cfg("deepseek-v2-lite-16b")
    prompts = [_prompt(cfg, 120, seed=23)]
    outs = []
    for uk in (False, True):
        eng = Engine(cfg, MODES["coopt"].replace(use_kernel=uk),
                     EngineConfig(num_lanes=2, max_len=256,
                                  prefill_buckets=(16, 32, 64, 128),
                                  long_window=32))
        outs.append(eng.generate(prompts, max_new_tokens=10))
    assert outs[0] == outs[1]


@pytest.mark.skipif(len(__import__("jax").devices()) < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8 (CI mesh-matrix job)")
def test_mla_kernel_engine_on_simulated_mesh():
    """mesh8 variant of the mla use_kernel engine run: the engine places
    the latent pool PAGES-SHARDED on a real (data=4, model=2) mesh and the
    fused latent kernels run per shard through the ``kernels.sharded``
    shard_map layer (global tables translated to per-shard holes, partial
    softmax states lse-merged) — one kernel hot path, single-host and
    distributed — staying greedy-identical to the meshless single-shard
    jnp reference."""
    from repro.launch.mesh import kv_shard_count, make_sim_mesh

    cfg = _cfg("deepseek-v2-lite-16b")
    mesh = make_sim_mesh(data=4, model=2)
    ns = kv_shard_count(mesh)
    assert ns == 4
    prompts = [_prompt(cfg, 70, seed=24), _prompt(cfg, 30, seed=25)]
    ecfg = EngineConfig(num_lanes=2, max_len=256,
                        prefill_buckets=(16, 32, 64, 128))

    ref = Engine(cfg, MODES["coopt"], ecfg)
    out_ref = ref.generate(prompts, max_new_tokens=5)

    eng = Engine(cfg, MODES["coopt"].replace(use_kernel=True), ecfg,
                 mesh=mesh)                   # num_shards derived = 4
    assert eng._kernel_ctx is not None
    out_mesh = eng.generate(prompts, max_new_tokens=5)
    assert out_ref == out_mesh
    assert eng.stats.num_shards == ns


def test_one_step_path_no_two_tier_scheduler():
    """The two-tier architecture is gone: the scheduler has no
    allow_chunked knob and the engine no monolithic prefill method."""
    from repro.serving.engine import Engine as E
    from repro.serving.scheduler import Scheduler as S
    assert not hasattr(E, "_run_prefill")
    assert not hasattr(E, "_run_decode")
    assert "allow_chunked" not in S.__init__.__code__.co_varnames
