"""Serving-engine behaviour: continuous batching, lane isolation, mode
agreement, SkipSet padding."""
import copy

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.coopt import MODES
from repro.data import RequestStream, sharegpt_stream
from repro.serving import Engine, EngineConfig, Request
from repro.serving.sampler import SamplingParams

CFG = get_config("qwen3-4b-reduced")
ECFG = EngineConfig(num_lanes=3, max_len=128,
                    prefill_buckets=(16, 32, 64, 128))


def _reqs(n, seed=0, max_new=8):
    rs = sharegpt_stream(CFG.vocab_size, n, seed=seed, scale=0.08)
    for r in rs:
        r.max_new_tokens = max_new
    return rs


def test_all_requests_complete_with_more_requests_than_lanes():
    eng = Engine(CFG, MODES["coopt"], ECFG)
    reqs = _reqs(7)
    for r in reqs:
        eng.add_request(r)
    eng.run()
    assert all(len(r.output) == r.max_new_tokens for r in reqs)
    assert eng.scheduler.free_lanes == list(range(ECFG.num_lanes - 1, -1, -1)) \
        or len(eng.scheduler.free_lanes) == ECFG.num_lanes


def test_greedy_modes_agree_excluding_fp8():
    """opt-gqa / opt-pa restructure compute only => identical greedy tokens
    (the paper's accuracy-preservation claim, exact on-device)."""
    reqs = _reqs(4, seed=3)
    outs = {}
    for mode in ("original", "opt-gqa", "opt-pa"):
        eng = Engine(CFG, MODES[mode], ECFG)
        rs = [copy.deepcopy(r) for r in reqs]
        for r in rs:
            eng.add_request(r)
        eng.run()
        outs[mode] = [r.output for r in rs]
    assert outs["original"] == outs["opt-gqa"] == outs["opt-pa"]


def test_lane_isolation():
    """A request admitted later must not change an in-flight request's
    greedy continuation (cache lane masking)."""
    r_solo = _reqs(1, seed=11)[0]
    eng = Engine(CFG, MODES["coopt"], ECFG)
    solo = copy.deepcopy(r_solo)
    eng.add_request(solo)
    eng.run()

    eng2 = Engine(CFG, MODES["coopt"], ECFG)
    both = copy.deepcopy(r_solo)
    eng2.add_request(both)
    eng2.step()                      # prefill r_solo
    eng2.step()                      # one decode step
    other = _reqs(1, seed=99)[0]     # now a second request arrives
    eng2.add_request(other)
    eng2.run()
    assert both.output == solo.output


def test_eos_stops_generation():
    eng = Engine(CFG, MODES["coopt"], ECFG)
    r = _reqs(1)[0]
    # every token is "EOS": generation must stop after the first one
    r.eos_token = None
    eng.add_request(r)
    eng.run()
    assert len(r.output) == r.max_new_tokens


def test_oversized_request_rejected():
    eng = Engine(CFG, MODES["coopt"], ECFG)
    r = Request(req_id=1, prompt=np.zeros(200, np.int32), max_new_tokens=8)
    eng.add_request(r)
    eng.run()
    assert r.output == []            # rejected: 200 + 8 > max_len 128


def test_sampling_temperature_changes_outputs():
    ecfg = EngineConfig(num_lanes=2, max_len=128,
                        prefill_buckets=(16, 32, 64),
                        sampling=SamplingParams(temperature=1.0, top_k=50))
    eng = Engine(CFG, MODES["coopt"], ecfg, params=None)
    reqs = _reqs(2, seed=5)
    for r in reqs:
        eng.add_request(r)
    eng.run()
    assert all(len(r.output) == r.max_new_tokens for r in reqs)


@pytest.mark.parametrize("arch", ["internvl2-2b", "whisper-small",
                                  "rwkv6-7b", "recurrentgemma-9b"])
def test_engine_other_families(arch):
    """Engine generality: vlm (patch prefix), enc-dec, SSM, hybrid."""
    cfg = get_config(arch + "-reduced")
    ecfg = EngineConfig(num_lanes=2, max_len=96, prefill_buckets=(16, 32))
    eng = Engine(cfg, MODES["coopt"], ecfg)
    reqs = sharegpt_stream(cfg.vocab_size, 3, seed=1, scale=0.05)
    for r in reqs:
        r.max_new_tokens = 4
        eng.add_request(r)
    eng.run()
    assert all(len(r.output) == 4 for r in reqs)


def test_chunked_prefill_oversized_prompt():
    """Prompts longer than the largest bucket are served via Sarathi-style
    chunked prefill and produce the same greedy tokens as a monolithic
    prefill through a big-bucket engine."""
    ecfg_small = EngineConfig(num_lanes=2, max_len=256,
                              prefill_buckets=(16, 32, 64))
    ecfg_big = EngineConfig(num_lanes=2, max_len=256,
                            prefill_buckets=(16, 32, 64, 128, 192))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, CFG.vocab_size, 150, dtype=np.int32)

    outs = []
    for ecfg in (ecfg_small, ecfg_big):
        eng = Engine(CFG, MODES["coopt"], ecfg)
        r = Request(req_id=1, prompt=prompt, max_new_tokens=6)
        eng.add_request(r)
        eng.run()
        assert len(r.output) == 6
        outs.append(r.output)
    # chunked and monolithic prefill round through the fp8 cache in
    # different orders, so only the first greedy token is schedule-stable
    # with random weights (logit-level equivalence is asserted in
    # tests/test_chunked_prefill.py)
    assert outs[0][0] == outs[1][0]
