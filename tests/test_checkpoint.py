"""Sharded-npz checkpoint roundtrip (incl. bf16/fp8 leaves)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.checkpoint.ckpt import checkpoint_step


def test_roundtrip_mixed_dtypes(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2, 2), jnp.bfloat16) * 1.5,
              "d": jnp.array([1, 2, 3], jnp.int32)},
        "e": (jnp.zeros((4,), jnp.float8_e4m3fn),),
    }
    save_checkpoint(str(tmp_path), tree, step=7)
    out = load_checkpoint(str(tmp_path), tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
    assert checkpoint_step(str(tmp_path)) == 7


def test_structure_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), {"a": jnp.zeros(3)})
    with pytest.raises(AssertionError):
        load_checkpoint(str(tmp_path), {"a": jnp.zeros(3),
                                        "b": jnp.zeros(3)})


def test_model_params_roundtrip(tmp_path):
    from repro.configs import get_config
    from repro.models import get_model
    m = get_model(get_config("qwen3-4b-reduced"))
    p = m.init(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), p)
    p2 = load_checkpoint(str(tmp_path), p)
    for x, y in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
