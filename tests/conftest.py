import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single CPU device; only launch/dryrun.py
# fakes 512 devices (and only in its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
