import importlib.util
import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single CPU device; only launch/dryrun.py
# fakes 512 devices (and only in its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The container has no ``hypothesis`` (declared in pyproject's dev extra; CI
# installs it). Register a deterministic shim so the property-test modules
# collect and RUN instead of aborting the whole suite at import time.
try:
    import hypothesis  # noqa: F401
except ImportError:
    _stub_path = os.path.join(os.path.dirname(__file__),
                              "_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _stub_path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
