"""Gradient accumulation (§Perf P0): microbatched train step must be
numerically equivalent to the monolithic one."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.steps import default_microbatches
from repro.training import adamw_init
from repro.training.train import make_train_step


@pytest.mark.parametrize("arch", ["qwen3-4b-reduced"])
def test_microbatched_equals_monolithic(arch):
    cfg = get_config(arch)
    from repro.models import get_model
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    B, S = 8, 32
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}

    s1 = jax.jit(make_train_step(cfg, num_microbatches=1))
    s4 = jax.jit(make_train_step(cfg, num_microbatches=4))
    p1, o1, m1 = s1(params, opt, batch)
    p4, o4, m4 = s4(params, opt, batch)
    # losses: mono-loss == mean of microbatch losses
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-3
    # resulting params agree to bf16 tolerance
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-2, rtol=2e-2)


def test_default_microbatches_policy():
    assert default_microbatches(get_config("mixtral-8x22b")) == 8    # MoE
    assert default_microbatches(get_config("deepseek-67b")) == 16    # 67B
    assert default_microbatches(get_config("qwen2.5-14b")) == 4
    assert default_microbatches(get_config("rwkv6-7b")) == 2
    assert default_microbatches(get_config("qwen3-4b")) == 1
    assert default_microbatches(get_config("recurrentgemma-9b")) == 8
    assert default_microbatches(get_config("internvl2-2b")) == 1
