"""Minimal deterministic stand-in for ``hypothesis`` (see conftest.py).

The container image does not ship hypothesis (it IS declared in the ``dev``
extra of pyproject.toml — CI installs the real thing). Rather than letting
five test modules die at collection and abort the whole tier-1 run, conftest
registers this shim when the real package is missing: ``@given`` draws a
fixed number of examples from a seeded RNG, so the property tests still
exercise their invariants, just without shrinking/database/replay.

Only the API surface these tests use is implemented: ``given``, ``settings``
and ``strategies.{integers, floats, booleans, sampled_from, lists, tuples}``.
"""
from __future__ import annotations

import types

import numpy as np

DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _floats(min_value, max_value, **_):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def _sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


def _lists(elem, min_size=0, max_size=10, **_):
    return _Strategy(lambda rng: [
        elem.example(rng)
        for _ in range(int(rng.integers(min_size, max_size + 1)))])


def _tuples(*elems):
    return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.booleans = _booleans
strategies.sampled_from = _sampled_from
strategies.lists = _lists
strategies.tuples = _tuples


def given(*arg_strats, **kw_strats):
    def deco(fn):
        # NOTE: the wrapper deliberately takes no parameters and does not
        # copy fn's signature — pytest must not mistake strategy params for
        # fixtures.
        def wrapper():
            n = getattr(wrapper, "_max_examples", DEFAULT_EXAMPLES)
            rng = np.random.default_rng(0)
            for _ in range(n):
                args = [s.example(rng) for s in arg_strats]
                kwargs = {k: s.example(rng) for k, s in kw_strats.items()}
                fn(*args, **kwargs)
        wrapper.__name__ = getattr(fn, "__name__", "given_wrapper")
        wrapper.__doc__ = getattr(fn, "__doc__", None)
        wrapper.__module__ = getattr(fn, "__module__", __name__)
        return wrapper
    return deco


def settings(max_examples: int = DEFAULT_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
