"""Hierarchical KV cache: host-DRAM spill tier + async prefetch.

Unit layer — BlockManager residency state machine with a fake spill sink
(DEVICE -> HOST on eviction, HOST -> IN_FLIGHT -> DEVICE on prefetch,
DROPPED on declined spills), two-tier audit invariants, host-LRU capacity,
the residency-first ``match_prefix`` API, and the CacheConfig constructor
shims.

Integration layer — the memory-pressure cell: a working set several times
the device pool, identical request mix with the tier ON vs OFF; the tier
must restore spilled prefixes (host hits > 0, strictly better hit rate)
while keeping greedy outputs BIT-IDENTICAL (spill/restore of fp8 pool
payloads is byte-lossless).
"""
import numpy as np
import pytest

from repro.cache.block_manager import (BlockManager, OutOfBlocks, PageHome,
                                       PageResidency, chain_hash_tokens)
from repro.cache.quant import decode_host_page, encode_host_page
from repro.configs.base import CacheConfig


def _mgr(num_pages=4, page_size=4, host_pages=8, sink=None):
    m = BlockManager(cfg=CacheConfig(num_pages=num_pages, page_size=page_size,
                                     host_pages=host_pages))
    m.spill_sink = sink if sink is not None else (lambda h, p, s: {"h": h})
    return m


def _fill_and_release(m, seq_id, toks):
    """Allocate + commit + free: leaves the full pages registered (LRU)."""
    m.allocate(seq_id, len(toks), token_ids=toks)
    m.commit_prefill(seq_id, len(toks), token_ids=toks)
    m.free(seq_id)


# ------------------------------------------------------------ unit: spill --
def test_spill_on_evict_lands_host():
    m = _mgr()
    toks = list(range(8))                      # 2 full pages
    _fill_and_release(m, 1, toks)
    h1 = chain_hash_tokens(toks, 1, 4)
    h2 = chain_hash_tokens(toks, 2, 4)
    assert m.residency(h1) is PageResidency.DEVICE
    # pressure: 4 fresh pages evict both registered pages -> spilled
    m.allocate(2, 16, token_ids=list(range(100, 116)))
    assert m.residency(h1) is PageResidency.HOST
    assert m.residency(h2) is PageResidency.HOST
    assert m.spilled_pages == 2 and m.host_resident_pages == 2
    assert m.audit() == []


def test_declined_spill_drops_page():
    m = _mgr(sink=lambda h, p, s: None)        # sink refuses every copy
    toks = list(range(8))
    _fill_and_release(m, 1, toks)
    m.allocate(2, 16, token_ids=list(range(100, 116)))
    h1 = chain_hash_tokens(toks, 1, 4)
    assert m.residency(h1) is PageResidency.DROPPED
    assert m.spilled_pages == 0 and m.host_resident_pages == 0
    assert m.audit() == []


def test_tier_off_never_spills():
    m = _mgr(host_pages=0)
    assert not m.host_tier_enabled
    toks = list(range(8))
    _fill_and_release(m, 1, toks)
    m.allocate(2, 16, token_ids=list(range(100, 116)))
    assert m.host_resident_pages == 0
    assert m.residency(chain_hash_tokens(toks, 1, 4)) \
        is PageResidency.DROPPED
    assert m.audit() == []


def test_host_lru_capacity_evicts_cold_end():
    m = _mgr(num_pages=4, host_pages=2)
    toks = list(range(16))                     # 4 full pages registered
    _fill_and_release(m, 1, toks)
    m.allocate(2, 16, token_ids=list(range(100, 116)))  # evict+spill all 4
    assert m.spilled_pages == 4
    assert m.host_resident_pages == 2          # capacity clamps the store
    assert m.host_evictions == 2
    res = [m.residency(chain_hash_tokens(toks, k, 4)) for k in (1, 2, 3, 4)]
    assert res.count(PageResidency.HOST) == 2      # survivors
    assert res.count(PageResidency.DROPPED) == 2   # past-capacity spills die
    assert m.audit() == []


# -------------------------------------------------------- unit: prefetch --
def test_prefetch_roundtrip_restores_device_hit():
    m = _mgr(num_pages=6)
    toks = list(range(9))                      # 2 full pages + tail
    _fill_and_release(m, 1, toks)
    m.allocate(2, 24, token_ids=list(range(100, 124)))  # evict -> spill
    h1 = chain_hash_tokens(toks, 1, 4)
    assert m.residency(h1) is PageResidency.HOST
    m.free(2)

    match = m.match_prefix(toks, len(toks))
    assert [p.residency for p in match.pages] == [PageResidency.HOST,
                                                  PageResidency.HOST]
    assert len(match.fetchable) == 2

    page, payload = m.begin_prefetch(h1, match.shard)
    assert m.residency(h1) is PageResidency.IN_FLIGHT
    assert m.staging_pages == 1
    assert m.page_states()[page].home is PageHome.STAGING
    assert m.pages_in_use == 0                 # staging is not "in use"
    assert m.commit_prefetch(h1)
    assert m.residency(h1) is PageResidency.DEVICE
    assert m.staging_pages == 0 and m.audit() == []

    # the restored page now serves allocate as a HOST-attributed hit
    _, cached = m.allocate(3, 9, token_ids=toks)
    assert cached == 4
    assert m.prefix_host_hits == 1 and m.prefix_device_hits == 0
    assert m.prefix_hits == 1                  # legacy total = dev + host
    m.free(3)
    assert m.audit() == []


def test_abort_prefetch_returns_payload_to_host():
    m = _mgr()
    toks = list(range(8))
    _fill_and_release(m, 1, toks)
    m.allocate(2, 16, token_ids=list(range(100, 116)))
    m.free(2)
    h1 = chain_hash_tokens(toks, 1, 4)
    m.begin_prefetch(h1, 0)
    assert m.abort_prefetch(h1)
    assert m.residency(h1) is PageResidency.HOST    # retriable
    assert m.staging_pages == 0 and m.prefetch_aborted == 1
    assert m.audit() == []


def test_commit_prefetch_loses_registration_race():
    m = _mgr(num_pages=6)
    toks = list(range(8))
    _fill_and_release(m, 1, toks)
    m.allocate(2, 24, token_ids=list(range(100, 124)))  # evict -> spill
    m.free(2)
    h1 = chain_hash_tokens(toks, 1, 4)
    m.begin_prefetch(h1, 0)
    # meanwhile the same prefix is recomputed and re-registered on device
    _fill_and_release(m, 3, toks)
    assert m.residency(h1) is PageResidency.DEVICE  # device takes priority
    assert not m.commit_prefetch(h1)                # race lost: page freed
    assert m.prefetch_aborted == 1 and m.staging_pages == 0
    assert m.audit() == []


def test_begin_prefetch_requires_host_residency():
    m = _mgr()
    with pytest.raises(KeyError):
        m.begin_prefetch(12345, 0)


def test_failed_allocate_rewinds_split_hit_stats():
    m = _mgr(num_pages=4)
    toks = list(range(8))
    _fill_and_release(m, 1, toks)
    m.allocate(2, 8, token_ids=list(range(100, 108)))  # 2 pages referenced
    # seq 3 matches the 2 registered pages but cannot get its 3rd page
    with pytest.raises(OutOfBlocks):
        m.allocate(3, 9, token_ids=toks)
    assert m.prefix_hits == 0
    assert m.prefix_device_hits == 0 and m.prefix_host_hits == 0
    assert m.audit() == []


# ------------------------------------------------- unit: config + shims --
def test_cache_config_validation():
    with pytest.raises(ValueError):
        CacheConfig(num_pages=-1)
    with pytest.raises(ValueError):
        CacheConfig(num_shards=0)
    with pytest.raises(ValueError):
        CacheConfig(host_pages=-2)


def test_block_manager_constructor_shims():
    legacy = BlockManager(16, page_size=8, num_shards=2)
    cfged = BlockManager(cfg=CacheConfig(num_pages=16, page_size=8,
                                         num_shards=2))
    assert legacy.num_pages == cfged.num_pages == 16
    assert legacy.page_size == cfged.page_size == 8
    assert legacy.num_shards == cfged.num_shards == 2
    with pytest.raises(TypeError):
        BlockManager(16, page_size=8, cfg=CacheConfig(num_pages=16,
                                                      page_size=8))
    with pytest.raises(ValueError):
        BlockManager(cfg=CacheConfig())        # unresolved sizes


def test_engine_config_cache_conflict_raises():
    from repro.serving import EngineConfig
    ecfg = EngineConfig(num_shards=2, cache=CacheConfig(num_shards=4))
    with pytest.raises(ValueError):
        ecfg.cache_config(16)
    # legacy mirrors fold in when cache is unset
    cc = EngineConfig(num_shards=2, enable_prefix_cache=False).cache_config(16)
    assert cc.num_shards == 2 and not cc.enable_prefix_cache
    assert cc.page_size == 16 and cc.num_pages > 0


# ------------------------------------------------------ unit: host codec --
def test_host_page_codec_roundtrip():
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    bf = jnp.asarray(rng.normal(size=(2, 8, 4)), jnp.bfloat16)
    f8 = jnp.asarray(rng.normal(size=(2, 8, 4)), jnp.bfloat16)
    # pass-through (quantize=False): bit-exact for every leaf
    hp = encode_host_page({"kv": bf, "scale": f8})
    assert not hp.encoded and not hp.scales
    assert bool(jnp.all(decode_host_page(hp, "kv") == bf))
    # quantize=True: bf16 leaves fp8-encoded (lossy), rest verbatim
    hq = encode_host_page({"kv": bf}, quantize=True)
    assert hq.encoded and "kv" in hq.scales
    err = jnp.max(jnp.abs(decode_host_page(hq, "kv").astype(jnp.float32)
                          - bf.astype(jnp.float32)))
    assert float(err) < 0.2


# ------------------------------------------- integration: memory pressure --
def _pressure_engine(host_pages):
    from repro.configs import get_config
    from repro.core.coopt import CoOptConfig
    from repro.serving import Engine, EngineConfig

    cfg = get_config("qwen3-4b-reduced")
    coopt = CoOptConfig(opt_kv=True, opt_gqa=True, opt_pa=True, page_size=16)
    cc = CacheConfig(num_pages=13, host_pages=host_pages, prefetch_depth=2)
    ecfg = EngineConfig(num_lanes=2, max_len=128,
                        prefill_buckets=(32, 64, 128), seed=0, cache=cc)
    return Engine(cfg, coopt, ecfg)


def _pressure_prompts():
    """8 distinct 3-page shared prefixes, replayed A..H A..H: every reuse
    distance exceeds the 12-page device pool (LRU worst case), working set
    ~= 24 prefix + 16 tail pages ~= 3-4x the pool."""
    rng = np.random.default_rng(0)
    prefixes = [rng.integers(10, 500, size=48).astype(np.int32)
                for _ in range(8)]
    prompts = []
    for _ in range(2):
        for p in prefixes:
            prompts.append(np.concatenate(
                [p, rng.integers(10, 500, size=8).astype(np.int32)]))
    return prompts


def test_memory_pressure_tier_bit_identity_and_hit_rate():
    prompts = _pressure_prompts()
    on = _pressure_engine(host_pages=64)
    outs_on = on.generate(prompts, max_new_tokens=8)
    off = _pressure_engine(host_pages=0)
    outs_off = off.generate(prompts, max_new_tokens=8)

    # greedy outputs bit-identical with the tier on vs off
    assert len(outs_on) == len(outs_off) == len(prompts)
    for a, b in zip(outs_on, outs_off):
        assert a == b

    s_on, s_off = on.stats, off.stats
    # the tier restored spilled prefixes: host-attributed hits exist, and
    # the hit RATE strictly beats the no-tier baseline
    assert s_on.prefix_host_hits > 0
    assert s_on.spilled_pages > 0 and s_on.prefetch_committed > 0
    assert s_on.prefix_hit_rate() > s_off.prefix_hit_rate()
    # split accounting is consistent with the legacy total
    assert (s_on.prefix_device_hits + s_on.prefix_host_hits
            == s_on.prefix_cache_hits)
    assert s_off.prefix_host_hits == 0 and s_off.spilled_pages == 0

    # both engines drain clean: audit invariants + zero pages in use
    for eng in (on, off):
        assert eng.scheduler.manager.audit() == []
        assert eng.scheduler.manager.pages_in_use == 0
        assert eng.scheduler.manager.staging_pages == 0
