"""Fused MLA latent-attention kernels (absorbed decode + chunk prefill off
the global FP8 latent pool) — parity sweeps vs the naive oracle AND vs the
jnp model path they replace, across {fp8, bf16} x {windowed, dense} x ragged
page tables with -1 holes; plus the launcher configure_for_backend wiring.
interpret=True on CPU."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.quant import quantize_latent
from repro.configs import get_config
from repro.core.coopt import MODES
from repro.core.opt_kv import decode_page_select, identity_page_table
from repro.kernels import ops, ref
from repro.models import mla as mla_mod

CFG = get_config("deepseek-v2-lite-16b-reduced")
H, DN, DR = CFG.num_heads, CFG.qk_nope_head_dim, CFG.qk_rope_head_dim
R, DV = CFG.kv_lora_rank, CFG.v_head_dim
SCALE = 1.0 / math.sqrt(DN + DR)


def _latent_pool(B, P, ps, fp8, seed=0):
    """Pool of B*P latent pages, lane-identity partitioned, with the LAST
    page of lane B-1 left unallocated (-1 hole in the ragged table)."""
    latf = jax.random.normal(jax.random.PRNGKey(seed), (B * P, ps, R + DR),
                             jnp.float32)
    pt = identity_page_table(B, B * P).at[B - 1, P - 1].set(-1)
    if fp8:
        lat, sc = quantize_latent(latf, R)
        return lat, sc, pt
    return latf.astype(jnp.bfloat16), None, pt


def _absorb_params(seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    return {"w_uk": jax.random.normal(ks[0], (R, H * DN)) * 0.05,
            "w_uv": jax.random.normal(ks[1], (R, H * DV)) * 0.05}


# ----------------------------------------------------------- decode kernel --
@pytest.mark.parametrize("fp8", [True, False])
@pytest.mark.parametrize("window,sink", [(0, 0), (32, 1), (16, 2)])
def test_latent_decode_kernel_vs_oracle(fp8, window, sink):
    B, P, ps = 2, 4, 16
    lat, sc, pt = _latent_pool(B, P, ps, fp8)
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    ql = jax.random.normal(ks[0], (B, H, R), jnp.float32)
    qr = jax.random.normal(ks[1], (B, H, DR), jnp.float32)
    cl = jnp.array([P * ps, 37], jnp.int32)      # lane 1: ragged, holed table
    phys, log = decode_page_select(cl, pt, ps, window=window,
                                   sink_pages=sink, opt_pa=True)
    out = ops.paged_latent_decode(ql, qr, lat, sc, cl, phys, log,
                                  sm_scale=SCALE, opt_kv=fp8, window=window,
                                  sink_pages=sink)
    exp = ref.paged_latent_decode_ref(ql, qr, lat, sc, cl, phys, log,
                                      sm_scale=SCALE, opt_kv=fp8,
                                      window=window, sink_pages=sink)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4)


@pytest.mark.parametrize("fp8", [True, False])
@pytest.mark.parametrize("window", [0, 32])
def test_mla_paged_decode_dispatch_parity(fp8, window):
    """The full model path: mla_paged_decode under use_kernel must match
    the jnp parity reference bit-for-bit after the bf16 output cast, for
    every mode x window combination — including -1 page holes."""
    B, P, ps = 2, 4, 16
    lat, sc, pt = _latent_pool(B, P, ps, fp8, seed=5)
    p = _absorb_params()
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    qn = jax.random.normal(ks[0], (B, H, DN)).astype(jnp.bfloat16)
    qr = jax.random.normal(ks[1], (B, H, DR)).astype(jnp.bfloat16)
    cl = jnp.array([P * ps, 37], jnp.int32)
    co = MODES["coopt" if fp8 else "original"]
    a = mla_mod.mla_paged_decode(qn, qr, lat, sc, cl, p, CFG,
                                 co.replace(use_kernel=False), window=window,
                                 sink_pages=1, page_table=pt)
    b = mla_mod.mla_paged_decode(qn, qr, lat, sc, cl, p, CFG,
                                 co.replace(use_kernel=True), window=window,
                                 sink_pages=1, page_table=pt)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-2)


def test_latent_decode_scattered_table():
    """Physically scattered pages (the refcounted allocator's normal state)
    decode identically to contiguous placement with the same content."""
    B, P, ps = 1, 4, 16
    lat, sc, _ = _latent_pool(B, P, ps, fp8=True, seed=8)
    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    ql = jax.random.normal(ks[0], (B, H, R), jnp.float32)
    qr = jax.random.normal(ks[1], (B, H, DR), jnp.float32)
    cl = jnp.array([P * ps], jnp.int32)
    log = jnp.arange(P, dtype=jnp.int32)[None]
    base = ops.paged_latent_decode(ql, qr, lat, sc, cl, log, log,
                                   sm_scale=SCALE, opt_kv=True)
    perm = jnp.array([3, 1, 0, 2], jnp.int32)
    lat_s = lat.at[perm].set(lat[:P])
    sc_s = sc.at[perm].set(sc[:P])
    out = ops.paged_latent_decode(ql, qr, lat_s, sc_s, cl, perm[None], log,
                                  sm_scale=SCALE, opt_kv=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=1e-5)


# ------------------------------------------------------------ chunk kernel --
@pytest.mark.parametrize("fp8", [True, False])
@pytest.mark.parametrize("window,sink", [(0, 0), (32, 1)])
def test_latent_chunk_kernel_vs_oracle(fp8, window, sink):
    """Chunk continuation with per-row positions: lane 0 a true chunk at
    [24, 32), lane 1 a decode lane (length-1 chunk, padding clamped) with
    its final page a -1 hole (never DMA'd)."""
    B, P, ps, S = 2, 4, 16, 8
    lat, sc, pt = _latent_pool(B, P, ps, fp8, seed=11)
    ks = jax.random.split(jax.random.PRNGKey(12), 2)
    ql = jax.random.normal(ks[0], (B, S, H, R), jnp.float32)
    qr = jax.random.normal(ks[1], (B, S, H, DR), jnp.float32)
    positions = jnp.stack([jnp.arange(24, 32),
                           jnp.full((S,), 40)]).astype(jnp.int32)
    out = ops.latent_chunk_prefill(ql, qr, positions, lat, sc, pt,
                                   sm_scale=SCALE, opt_kv=fp8,
                                   window=window, sink_pages=sink)
    exp = ref.latent_chunk_prefill_ref(ql, qr, positions, lat, sc, pt,
                                       sm_scale=SCALE, opt_kv=fp8,
                                       window=window, sink_pages=sink)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4)


@pytest.mark.parametrize("fp8", [True, False])
@pytest.mark.parametrize("window", [0, 32])
def test_mla_chunk_attention_dispatch_parity(fp8, window):
    B, P, ps, S = 2, 4, 16, 8
    lat, sc, pt = _latent_pool(B, P, ps, fp8, seed=13)
    p = _absorb_params()
    ks = jax.random.split(jax.random.PRNGKey(14), 2)
    qn = jax.random.normal(ks[0], (B, S, H, DN)).astype(jnp.bfloat16)
    qr = jax.random.normal(ks[1], (B, S, H, DR)).astype(jnp.bfloat16)
    positions = jnp.stack([jnp.arange(24, 32),
                           jnp.full((S,), 40)]).astype(jnp.int32)
    co = MODES["coopt" if fp8 else "original"]
    a = mla_mod.mla_chunk_attention(qn, qr, lat, sc, positions, pt, p, CFG,
                                    co.replace(use_kernel=False),
                                    window=window, sink_pages=1)
    b = mla_mod.mla_chunk_attention(qn, qr, lat, sc, positions, pt, p, CFG,
                                    co.replace(use_kernel=True),
                                    window=window, sink_pages=1)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-2)


# --------------------------------------------------- backend configuration --
def test_configure_for_backend_flips_interpret(monkeypatch):
    """Under a (faked) TPU backend the launchers' configure_for_backend()
    call must flip interpret mode OFF; any other backend keeps it on."""
    monkeypatch.setattr(ops, "INTERPRET", ops.INTERPRET)  # restore on exit
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    ops.configure_for_backend()
    assert ops.INTERPRET is False
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    ops.configure_for_backend()
    assert ops.INTERPRET is True


def test_launchers_call_configure_for_backend(monkeypatch):
    """serve_workload, make_step (use_kernel engine setup) and
    benchmarks.run must all invoke ops.configure_for_backend — the module
    docstring promised it; now the launchers actually do it."""
    calls = []
    monkeypatch.setattr(ops, "configure_for_backend",
                        lambda: calls.append(1))

    from repro.launch.serve import serve_workload
    serve_workload("qwen3-4b-reduced", "original", requests=1, num_lanes=1,
                   max_len=64, max_new_tokens=1)
    assert len(calls) == 1

    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_step
    from repro.core.coopt import COOPT
    make_step("qwen3-4b-reduced", "decode_32k", make_host_mesh(),
              COOPT.replace(use_kernel=True))
    assert len(calls) == 2

    from benchmarks.run import main
    main(["--only", "nosuchbench"])
    assert len(calls) == 3
