"""Sharding-rule unit tests + a reduced-config end-to-end jit on a tiny
forced-multi-device mesh is NOT possible here (device count is locked to 1
in the test process by design) — the full-mesh path is exercised by
launch/dryrun.py in its own process; these tests cover the pure logic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as PS

from repro.configs import get_config
from repro.launch.steps import (CACHE_RULES, WEIGHT_RULES, axes_pspec,
                                effective_config, long_window_for, make_step,
                                ShapeSkipped)
from repro.configs.shapes import SHAPES
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.models.layers import Spec, spec_pspec


class FakeMesh:
    """Just enough of a Mesh for the rule engine."""
    def __init__(self, shape):
        self.shape = shape


M = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_weight_pspec_2d_sharding():
    ps = axes_pspec((60, 7168, 7168), ("layers", "d_in", "d_out"), M,
                    WEIGHT_RULES)
    assert ps == PS(None, "data", "model")


def test_non_divisible_axis_dropped():
    # yi: 56 heads * 128 = 7168 divisible, but raw 56 is not
    ps = axes_pspec((60, 56, 128), ("layers", "d_out", None), M,
                    WEIGHT_RULES)
    assert ps == PS(None, None, None)


def test_whisper_vocab_not_divisible():
    ps = axes_pspec((51865, 768), ("vocab", "d_out"), M, WEIGHT_RULES)
    assert ps == PS(None, "model")


def test_experts_take_priority_over_d_in():
    ps = axes_pspec((27, 64, 2048, 1408), ("layers", "experts", "d_in",
                                           "d_out"), M, WEIGHT_RULES)
    assert ps == PS(None, "data", None, "model")   # d_in loses "data"


def test_mixtral_8_experts_fall_back():
    ps = axes_pspec((56, 8, 6144, 16384), ("layers", "experts", "d_in",
                                           "d_out"), M, WEIGHT_RULES)
    assert ps == PS(None, None, "data", "model")


def test_cache_batch1_pages_take_data():
    # long_500k: batch=1 unshardable, pages take (pod, data)
    ps = axes_pspec((60, 2, 1, 8192, 64, 8, 128),
                    ("layers", None, "batch", "pages", None, "kv_heads",
                     "head_dim"), M, CACHE_RULES)
    assert ps == PS(None, None, None, ("pod", "data"), None, None, "model")


def test_cache_batch128_takes_pod_data():
    ps = axes_pspec((60, 2, 128, 512, 64, 8, 128),
                    ("layers", None, "batch", "pages", None, "kv_heads",
                     "head_dim"), M, CACHE_RULES)
    assert ps == PS(None, None, ("pod", "data"), None, None, None, "model")


def test_long500k_policy():
    for arch, expect_window in [("qwen3-4b", True), ("deepseek-67b", True),
                                ("mixtral-8x22b", False),
                                ("rwkv6-7b", False),
                                ("recurrentgemma-9b", False)]:
        cfg = get_config(arch)
        lw = long_window_for(cfg, SHAPES["long_500k"])
        assert (lw > 0) == expect_window, arch


def test_whisper_long500k_skipped():
    with pytest.raises(ShapeSkipped):
        effective_config(get_config("whisper-small"), SHAPES["long_500k"])


def test_make_step_host_mesh_reduced_runs():
    """End-to-end: a reduced decode step jitted with shardings on the
    1-device host mesh actually executes."""
    mesh = make_host_mesh()
    bundle = make_step("qwen3-4b-reduced", "decode_32k", mesh)
    # replace the abstract args with tiny real ones
    cfg = bundle.cfg
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = SHAPES["decode_32k"].global_batch
    cache = model.init_cache(B, 128, bundle.coopt)
    batch = {"token": jnp.zeros((B, 1), jnp.int32)}
    with mesh:
        logits, cache2 = jax.jit(bundle.fn)(params, batch, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


def test_all_arch_shape_bundles_build():
    """make_step constructs (abstract) for every live (arch x shape) cell —
    catches spec/sharding construction bugs without compiling."""
    from repro.configs import ARCH_IDS
    mesh = make_host_mesh()
    built, skipped = 0, 0
    for arch in ARCH_IDS:
        for shape in SHAPES:
            try:
                b = make_step(arch, shape, mesh)
                assert b.args and b.in_shardings
                built += 1
            except ShapeSkipped:
                skipped += 1
    assert built == 39 and skipped == 1
