"""Shared-pool serving semantics: prefix-cache reuse, preempt-and-resume,
and rejection surfacing (the PR's acceptance criteria).

All comparisons run greedy (temperature 0) so scheduling differences can
only show up as genuine numeric differences.
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.coopt import MODES, ORIGINAL
from repro.core.opt_kv import identity_slots
from repro.models import get_model
from repro.serving import Engine, EngineConfig, Request
from repro.serving.request import RequestState

CFG = get_config("qwen3-4b-reduced")


def _prompt(rng, n):
    return rng.integers(0, CFG.vocab_size, n, dtype=np.int32)


# ---------------------------------------------------------------- prefix --
def test_prefix_cache_model_level_bit_identical_logits():
    """Warm prefill (shared prefix pages reused, only the tail computed)
    returns BIT-IDENTICAL last-token logits vs recomputing everything with
    the same chunk schedule — reused pages hold exactly the bytes the cold
    path would have written."""
    m = get_model(CFG)
    p = m.init(jax.random.PRNGKey(0))
    coopt = MODES["coopt"]                    # fp8 cache: bytes, not floats
    ps = coopt.page_size
    B, prefix_len, tail_len = 2, ps, 16       # prefix = exactly one page
    rng = np.random.default_rng(0)
    prefix = _prompt(rng, prefix_len)
    tail = _prompt(rng, tail_len)

    cache = m.init_cache(B, 2 * ps, coopt)
    P_total = cache["kv"].shape[2]            # 4 pages: lane0 {0,1} lane1 {2,3}

    def chunk(cache, lane, tokens, start, page_table):
        n = len(tokens)
        toks = np.zeros((B, n), np.int32)
        toks[lane] = tokens
        pos = np.broadcast_to(np.arange(start, start + n), (B, n))
        slots = np.full((B, n), -1, np.int32)
        slots[lane] = np.asarray(
            identity_slots(B, jnp.asarray(pos), P_total, ps))[lane]
        logits, cache = m.prefill(
            p, {"tokens": jnp.asarray(toks),
                "positions": jnp.asarray(pos.astype(np.int32)),
                "slot_idx": jnp.asarray(slots),
                "page_table": jnp.asarray(page_table)}, cache, coopt)
        return logits, cache

    own = np.asarray(jnp.stack([jnp.array([0, 1]), jnp.array([2, 3])]),
                     np.int32)
    # lane 0: cold — prefix chunk then tail chunk into its own pages
    _, cache = chunk(cache, 0, prefix, 0, own)
    cold_logits, cache = chunk(cache, 0, tail, prefix_len, own)
    # lane 1 COLD REFERENCE: same two chunks into its own pages
    _, cache_ref = chunk(cache, 1, prefix, 0, own)
    ref_logits, _ = chunk(cache_ref, 1, tail, prefix_len, own)
    # lane 1 WARM: skip the prefix — page table aliases lane 0's prefix page
    shared = own.copy()
    shared[1, 0] = 0                           # lane 1 reads lane 0's page 0
    warm_logits, _ = chunk(cache, 1, tail, prefix_len, shared)

    np.testing.assert_array_equal(np.asarray(ref_logits[1]),
                                  np.asarray(warm_logits[1]))
    np.testing.assert_array_equal(np.asarray(cold_logits[0]),
                                  np.asarray(warm_logits[1]))


def test_prefix_cache_engine_fewer_pages_and_same_tokens():
    """Acceptance: two requests sharing a >= 1-page prompt prefix allocate
    fewer total pages than two cold requests (pool-utilization stat) and
    generate identical greedy tokens."""
    rng = np.random.default_rng(1)
    ps = MODES["coopt"].page_size
    shared = _prompt(rng, 2 * ps)             # 2 full shared pages
    tails = [_prompt(rng, 7), _prompt(rng, 9)]
    prompts = [np.concatenate([shared, t]) for t in tails]
    ecfg = EngineConfig(num_lanes=2, max_len=256,
                        prefill_buckets=(16, 32, 64, 128, 256))

    outs, stats = {}, {}
    for label, enabled in (("warm", True), ("cold", False)):
        eng = Engine(CFG, MODES["coopt"],
                     EngineConfig(**{**ecfg.__dict__,
                                     "enable_prefix_cache": enabled}))
        # sequential: the second request arrives after the first finished,
        # so its full prompt pages are committed and reusable
        o1 = eng.generate([prompts[0]], max_new_tokens=4)
        o2 = eng.generate([prompts[1]], max_new_tokens=4)
        outs[label] = (o1, o2)
        stats[label] = eng.stats

    assert outs["warm"] == outs["cold"]
    assert stats["warm"].prefix_cache_hits >= 2          # 2 full pages reused
    assert stats["warm"].fresh_pages_allocated < \
        stats["cold"].fresh_pages_allocated
    assert stats["cold"].prefix_cache_hits == 0


# ------------------------------------------------------------ preemption --
def test_preempt_and_resume_matches_unconstrained_greedy():
    """Acceptance: an over-subscribed workload (aggregate demand > pool)
    completes via preemption with outputs identical to an unconstrained run
    under greedy sampling."""
    rng = np.random.default_rng(2)
    prompts = [_prompt(rng, 50), _prompt(rng, 50)]
    mode = ORIGINAL                           # bf16: bit-stable recompute

    # pool = lanes * pages(max_len) - 1 = 3 pages of 64 tokens; demand =
    # 2 * ceil(70/64) = 4 pages -> must preempt
    tight = EngineConfig(num_lanes=2, max_len=128,
                         prefill_buckets=(16, 32, 64, 128))
    roomy = EngineConfig(num_lanes=2, max_len=256,
                         prefill_buckets=(16, 32, 64, 128, 256))

    eng_t = Engine(CFG, mode, tight)
    out_t = eng_t.generate(prompts, max_new_tokens=20)
    eng_r = Engine(CFG, mode, roomy)
    out_r = eng_r.generate(prompts, max_new_tokens=20)

    assert eng_t.stats.preemptions > 0
    assert eng_r.stats.preemptions == 0
    assert all(len(o) == 20 for o in out_t)
    assert out_t == out_r


def test_pool_smaller_than_static_partition_still_serves():
    """The point of the shared pool: lanes whose requests are short leave
    room for a long one — aggregate > per-lane share but < pool."""
    rng = np.random.default_rng(3)
    ecfg = EngineConfig(num_lanes=4, max_len=192,
                        prefill_buckets=(16, 32, 64, 128, 192))
    eng = Engine(CFG, MODES["coopt"], ecfg)
    # one long request (2.5 pages) + three tiny ones: under the old static
    # partition each lane capped at 3 pages; here they share 11
    prompts = [_prompt(rng, 150)] + [_prompt(rng, 8) for _ in range(3)]
    outs = eng.generate(prompts, max_new_tokens=4)
    assert all(len(o) == 4 for o in outs)
    assert eng.stats.peak_pages_in_use <= eng.stats.pool_pages


# ------------------------------------------------------------- rejection --
def test_rejected_state_surfaced_from_generate():
    eng = Engine(CFG, MODES["coopt"],
                 EngineConfig(num_lanes=2, max_len=128,
                              prefill_buckets=(16, 32, 64, 128)))
    ok = _prompt(np.random.default_rng(4), 20)
    too_long = _prompt(np.random.default_rng(5), 200)   # 200 + 8 > 128
    reqs = eng.generate([ok, too_long], max_new_tokens=8,
                        return_requests=True)
    assert reqs[0].state is RequestState.FINISHED
    assert len(reqs[0].output) == 8
    assert reqs[1].state is RequestState.REJECTED
    assert reqs[1].output == []
    assert eng.stats.rejected == 1


def test_prompt_over_largest_bucket_served_chunked_every_family():
    """The old "no bucket -> REJECT" rule is gone: every family serves a
    prompt larger than the largest bucket via chunked continuation prefill
    (here rwkv6, the family that used to reject)."""
    cfg = get_config("rwkv6-7b-reduced")
    eng = Engine(cfg, MODES["coopt"],
                 EngineConfig(num_lanes=2, max_len=256,
                              prefill_buckets=(16, 32)))
    big = _prompt(np.random.default_rng(6), 100)        # > bucket 32
    reqs = eng.generate([big], max_new_tokens=4, return_requests=True)
    assert reqs[0].state is RequestState.FINISHED
    assert len(reqs[0].output) == 4
    assert eng.stats.rejected == 0
    assert eng.stats.prefill_calls > 1                  # really chunked
