"""RWKV-6 chunked-scan vs sequential-step equivalence (regression for the
clamped-ratio bug — EXPERIMENTS.md §Accuracy note).

The chunked form must match the O(1) decode recurrence exactly even for
extreme data-dependent decays (w down to exp(-exp(4))), because serving
mixes the two paths (chunked prefill -> step decode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.rwkv6 import RWKV6Model


def _seq(r, k, v, w, u, s0):
    st_, outs = s0, []
    for t in range(r.shape[1]):
        o, st_ = RWKV6Model._wkv_step(r[:, t], k[:, t], v[:, t], w[:, t],
                                      u, st_)
        outs.append(o)
    return jnp.stack(outs, 1), st_


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), ww_max=st.floats(-1.0, 4.0))
def test_chunked_equals_sequential(seed, ww_max):
    key = jax.random.PRNGKey(seed)
    B, S, H, D = 2, 64, 2, 4
    ks = jax.random.split(key, 4)
    r = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    ww = jax.random.uniform(ks[3], (B, S, H, D), minval=-3.0, maxval=ww_max)
    w = jnp.exp(-jnp.exp(ww))          # extreme decays exercise underflow
    u = jax.random.normal(key, (H, D)) * 0.1
    s0 = jnp.zeros((B, H, D, D))
    seq_out, seq_st = _seq(r, k, v, w, u, s0)
    ch_out, ch_st = RWKV6Model._wkv_chunked(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(ch_out), np.asarray(seq_out),
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(ch_st), np.asarray(seq_st),
                               atol=2e-3)


def test_chunk_boundary_state_handoff():
    """Chunked prefix state + one sequential step == full chunked run."""
    key = jax.random.PRNGKey(7)
    B, S, H, D = 1, 33, 2, 8
    ks = jax.random.split(key, 4)
    r = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, D))))
    u = jnp.zeros((H, D))
    s0 = jnp.zeros((B, H, D, D))
    _, st32 = RWKV6Model._wkv_chunked(r[:, :32], k[:, :32], v[:, :32],
                                      w[:, :32], u, s0)
    o_step, _ = RWKV6Model._wkv_step(r[:, 32], k[:, 32], v[:, 32],
                                     w[:, 32], u, st32)
    seq_out, _ = _seq(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o_step), np.asarray(seq_out[:, 32]),
                               atol=1e-4)
