"""Sampler properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serving.sampler import sample, top_k_mask, top_p_mask


def test_greedy_is_argmax():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32)),
                         jnp.float32)
    toks = sample(logits, jax.random.PRNGKey(0), temperature=0.0)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), k=st.integers(1, 8))
def test_top_k_only_samples_top_k(seed, k):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)
    toks = np.asarray(sample(logits, jax.random.PRNGKey(seed),
                             temperature=1.0, top_k=k))
    for b in range(2):
        topk = set(np.argsort(np.asarray(logits[b]))[-k:].tolist())
        assert int(toks[b]) in topk


def test_top_k_ties_broken_by_rank():
    """Regression: four exactly-tied logits with top_k=2 must keep TWO
    tokens — the old ``lf < kth`` mask kept every token tied with the k-th
    logit, inflating the candidate set beyond k (common after low-precision
    logits quantize the tail)."""
    logits = jnp.zeros((1, 4))
    mask = np.asarray(top_k_mask(logits, 2))
    assert mask.tolist() == [[True, True, False, False]]
    for s in range(30):
        t = int(sample(logits, jax.random.PRNGKey(s), temperature=1.0,
                       top_k=2)[0])
        assert t in (0, 1)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), k=st.integers(1, 16))
def test_top_k_mask_keeps_exactly_k(seed, k):
    """Property: even with heavy ties the mask keeps EXACTLY k tokens, and
    they form a prefix of the stable descending sort."""
    rng = np.random.default_rng(seed)
    lf = jnp.asarray(np.round(rng.normal(size=(3, 32)) * 2) / 2, jnp.float32)
    mask = np.asarray(top_k_mask(lf, k))
    assert (mask.sum(-1) == k).all()
    order = np.argsort(-np.asarray(lf), axis=-1, kind="stable")
    for b in range(3):
        assert set(np.flatnonzero(mask[b]).tolist()) == \
            set(order[b, :k].tolist())


def test_top_p_excludes_tail():
    # one dominant token (p > 0.95): top_p=0.9 must always pick it
    logits = jnp.zeros((1, 16)).at[0, 3].set(10.0)
    for s in range(20):
        t = sample(logits, jax.random.PRNGKey(s), temperature=1.0, top_p=0.9)
        assert int(t[0]) == 3


def test_top_p_ties_broken_by_rank():
    """Regression: four exactly-tied logits with top_p=0.26 must keep TWO
    tokens (0.25 + 0.25 >= 0.26), not all four — the old ``lf < cutoff``
    mask kept every token tied with the cutoff logit, inflating the nucleus
    (common after top-k masking quantizes logits)."""
    logits = jnp.zeros((1, 4))
    mask = np.asarray(top_p_mask(logits, 0.26))
    assert mask.tolist() == [[True, True, False, False]]
    for s in range(30):
        t = int(sample(logits, jax.random.PRNGKey(s), temperature=1.0,
                       top_p=0.26)[0])
        assert t in (0, 1)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000),
       top_p=st.floats(0.05, 0.95))
def test_top_p_keeps_smallest_sufficient_set(seed, top_p):
    """Property: the nucleus is the SMALLEST rank-prefix whose probability
    mass reaches top_p — kept mass >= top_p, and dropping the kept token of
    lowest rank takes it below. Logits are quantized to force ties."""
    rng = np.random.default_rng(seed)
    lf = jnp.asarray(np.round(rng.normal(size=(3, 32)) * 2) / 2, jnp.float32)
    mask = np.asarray(top_p_mask(lf, top_p))
    probs = np.asarray(jax.nn.softmax(lf, axis=-1))
    order = np.argsort(-np.asarray(lf), axis=-1, kind="stable")
    for b in range(3):
        kept_ranked = [i for i in order[b] if mask[b, i]]
        kept_mass = probs[b, kept_ranked].sum()
        assert kept_mass >= top_p - 1e-5
        assert kept_mass - probs[b, kept_ranked[-1]] < top_p + 1e-5
        # the nucleus is a PREFIX of the descending-sorted order
        n = len(kept_ranked)
        assert set(kept_ranked) == set(order[b, :n].tolist())


def test_temperature_spreads_distribution():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0]], jnp.float32)
    seen = {int(sample(logits, jax.random.PRNGKey(s), temperature=5.0)[0])
            for s in range(200)}
    assert len(seen) >= 3      # high temperature explores
