"""Sampler properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serving.sampler import sample


def test_greedy_is_argmax():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32)),
                         jnp.float32)
    toks = sample(logits, jax.random.PRNGKey(0), temperature=0.0)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), k=st.integers(1, 8))
def test_top_k_only_samples_top_k(seed, k):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)
    toks = np.asarray(sample(logits, jax.random.PRNGKey(seed),
                             temperature=1.0, top_k=k))
    for b in range(2):
        topk = set(np.argsort(np.asarray(logits[b]))[-k:].tolist())
        assert int(toks[b]) in topk


def test_top_p_excludes_tail():
    # one dominant token (p > 0.95): top_p=0.9 must always pick it
    logits = jnp.zeros((1, 16)).at[0, 3].set(10.0)
    for s in range(20):
        t = sample(logits, jax.random.PRNGKey(s), temperature=1.0, top_p=0.9)
        assert int(t[0]) == 3


def test_temperature_spreads_distribution():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0]], jnp.float32)
    seen = {int(sample(logits, jax.random.PRNGKey(s), temperature=5.0)[0])
            for s in range(200)}
    assert len(seen) >= 3      # high temperature explores
