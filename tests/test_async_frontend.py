"""AsyncEngine pipeline semantics: greedy token identity vs the sync loop
under interleaved submissions, cancel releasing pool pages mid-stream,
zero steady-state traces after AOT warmup, and TTFT/queue-wait provenance
(latency anchored at submission).

All generation runs greedy (temperature 0) so any pipeline reordering
could only show up as a genuine token difference.
"""
import copy

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.coopt import MODES
from repro.kernels import ops
from repro.serving import AsyncEngine, Engine, EngineConfig, Request
from repro.serving.request import RequestState
from repro.serving.sampler import SamplingParams

CFG = get_config("qwen3-4b-reduced")
ops.configure_for_backend()


def _engine(num_lanes=4, max_len=128, pack=False, seed=0):
    ecfg = EngineConfig(num_lanes=num_lanes, max_len=max_len,
                        prefill_buckets=(32, 64, 128),
                        sampling=SamplingParams(temperature=0.0),
                        seed=seed, pack_prefill=pack)
    return Engine(CFG, MODES["coopt"], ecfg)


def _prompts(n, rng, lo=4, hi=40):
    return [rng.integers(0, CFG.vocab_size, int(rng.integers(lo, hi)),
                         dtype=np.int32) for _ in range(n)]


def _sync_outputs(prompts, max_new_tokens):
    eng = _engine()
    return eng.generate(prompts, max_new_tokens=max_new_tokens)


# ---------------------------------------------------------- identity -----
def test_async_matches_sync_greedy_interleaved():
    """Interleaved submissions (a second wave submitted while the first is
    mid-decode) produce BIT-IDENTICAL greedy tokens to the synchronous
    loop serving the same prompts."""
    rng = np.random.default_rng(11)
    prompts = _prompts(6, rng)
    sync_out = _sync_outputs(prompts, 12)

    eng = _engine()
    fe = AsyncEngine(eng, warmup=True)
    streams = [fe.submit(p, max_new_tokens=12) for p in prompts[:3]]
    # run a few pipeline turns so wave 1 is mid-decode, then submit wave 2
    for _ in range(6):
        fe._loop_once()
    streams += [fe.submit(p, max_new_tokens=12) for p in prompts[3:]]
    fe.run_until_idle()

    async_out = [list(s.req.output) for s in streams]
    assert async_out == [list(o) for o in sync_out]
    assert all(s.req.state is RequestState.FINISHED for s in streams)


def test_stream_yields_all_tokens_in_order():
    rng = np.random.default_rng(3)
    prompts = _prompts(2, rng)
    eng = _engine()
    fe = AsyncEngine(eng, warmup=True)
    handles = [fe.submit(p, max_new_tokens=8) for p in prompts]
    fe.run_until_idle()
    for h in handles:
        assert list(fe.stream(h)) == list(h.req.output)
        assert len(h.req.output) == 8


# ------------------------------------------------------------ cancel -----
def test_cancel_mid_stream_releases_pool_pages_and_lane():
    """cancel() mid-generation drops the request (state CANCELLED), frees
    its lane, and returns the pool to baseline: after the surviving
    requests finish, zero pages stay referenced."""
    rng = np.random.default_rng(7)
    prompts = _prompts(3, rng, lo=8, hi=24)
    eng = _engine(num_lanes=4)
    fe = AsyncEngine(eng, warmup=True)
    victim = fe.submit(prompts[0], max_new_tokens=64)
    others = [fe.submit(p, max_new_tokens=10) for p in prompts[1:]]
    # let the victim produce a few tokens, then abandon it mid-stream
    for _ in range(8):
        fe._loop_once()
    assert len(victim.req.output) > 0
    fe.cancel(victim)
    fe.run_until_idle()

    assert victim.req.state is RequestState.CANCELLED
    assert all(o.req.state is RequestState.FINISHED for o in others)
    assert len(victim.req.output) < 64          # stopped early
    # lane freed and every page back to the allocator
    assert not eng.scheduler.running
    eng._update_pool_stats()
    assert eng.stats.pages_in_use == 0
    # the victim's stream is closed: iteration terminates and yields
    # exactly the tokens that were emitted before the cancel landed
    assert list(victim) == list(victim.req.output)


def test_cancelled_tokens_never_reach_stream_after_cancel():
    rng = np.random.default_rng(9)
    eng = _engine(num_lanes=2)
    fe = AsyncEngine(eng, warmup=True)
    h = fe.submit(_prompts(1, rng)[0], max_new_tokens=64)
    for _ in range(4):
        fe._loop_once()
    fe.cancel(h)
    n_at_cancel = len(h.req.output)
    fe.run_until_idle()
    # the pipeline may deliver at most the already-dispatched steps
    assert len(h.req.output) <= n_at_cancel + 2


# -------------------------------------------------- AOT / zero-retrace ---
def test_zero_traces_after_warmup():
    """After ``warmup()`` pre-compiles the bucket lattice, a serving run
    performs ZERO new jit traces and never misses the AOT cache."""
    rng = np.random.default_rng(5)
    prompts = _prompts(5, rng)
    eng = _engine()
    fe = AsyncEngine(eng, warmup=True)
    assert fe.warmed_shapes > 0
    traces = dict(eng.trace_counts)
    for p in prompts:
        fe.submit(p, max_new_tokens=10)
    fe.run_until_idle()
    assert eng.aot_misses == 0
    assert eng.trace_counts == traces


def test_warmup_covers_packed_lattice_too():
    eng = _engine(pack=True)
    fe = AsyncEngine(eng, warmup=True)
    traces = dict(eng.trace_counts)
    rng = np.random.default_rng(13)
    for p in _prompts(5, rng, lo=4, hi=20):
        fe.submit(p, max_new_tokens=6)
    fe.run_until_idle()
    assert eng.aot_misses == 0
    assert eng.trace_counts == traces
    assert eng.stats.packed_steps > 0


# ------------------------------------------------- latency provenance ----
def test_ttft_measured_from_submission_includes_queue_wait():
    """More requests than lanes: the overflow request queues, so its TTFT
    (anchored at submit time) must include the queue wait, and
    ``queue_wait_s`` percentiles are populated."""
    rng = np.random.default_rng(21)
    prompts = _prompts(5, rng, lo=8, hi=24)
    eng = _engine(num_lanes=2)
    fe = AsyncEngine(eng, warmup=True)
    for p in prompts:
        fe.submit(p, max_new_tokens=8)
    fe.run_until_idle()

    s = eng.stats
    assert len(s.ttft_s) == len(prompts)
    assert len(s.queue_wait_s) == len(prompts)
    assert all(t > 0 for t in s.ttft_s)
    assert all(q >= 0 for q in s.queue_wait_s)
    # every TTFT contains that request's queue wait
    assert all(t >= q for t, q in zip(sorted(s.ttft_s),
                                      sorted(s.queue_wait_s)))
    summary = s.latency_summary()
    for k in ("ttft_p50_s", "ttft_p95_s", "tpot_p50_s", "tpot_p95_s",
              "queue_wait_p50_s", "queue_wait_p95_s"):
        assert k in summary
    # with 5 requests on 2 lanes SOMEONE waited for a lane
    assert summary["queue_wait_p95_s"] > 0


def test_sync_generate_stamps_real_submission_times():
    rng = np.random.default_rng(2)
    eng = _engine(num_lanes=2)
    reqs = eng.generate(_prompts(4, rng, lo=6, hi=16), max_new_tokens=4,
                        return_requests=True)
    assert all(r.submit_time > 0 for r in reqs)
    assert all(r.admit_time >= r.submit_time for r in reqs)
    assert len(eng.stats.queue_wait_s) == 4
