"""Per-architecture smoke tests (deliverable f): every assigned arch as a
REDUCED variant of the same family — forward + one train step + prefill +
decode on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_IDS, get_config
from repro.configs.shapes import InputShape
from repro.core.coopt import COOPT
from repro.models import get_model
from repro.training import Trainer


def _batch(m, cfg, B, S, key):
    sh = InputShape("t", S, B, "train")
    out = {}
    for k, v in m.input_specs(sh).items():
        if v.dtype == jnp.int32:
            out[k] = jax.random.randint(key, v.shape, 0, cfg.vocab_size)
        else:
            out[k] = jax.random.normal(key, v.shape).astype(v.dtype)
    return out


@pytest.mark.parametrize("arch", ALL_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch + "-reduced")
    assert cfg.num_layers <= 3 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    batch = _batch(m, cfg, B, S, jax.random.PRNGKey(1))
    logits, _aux = m.forward(p, batch, COOPT)
    S_text = S - (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_text, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.parametrize("arch", ALL_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch + "-reduced")
    m = get_model(cfg)
    tr = Trainer(cfg, lr=1e-3)
    B, S = 2, 32
    batch = _batch(m, cfg, B, S, jax.random.PRNGKey(2))
    S_text = S - (cfg.num_patches if cfg.family == "vlm" else 0)
    batch["labels"] = jax.random.randint(jax.random.PRNGKey(3),
                                         (B, S_text), 0, cfg.vocab_size)
    metrics = tr.step(batch)
    assert np.isfinite(metrics["loss"])
    assert metrics["loss"] > 0


@pytest.mark.parametrize("arch", ALL_IDS)
def test_prefill_decode_roundtrip(arch):
    cfg = get_config(arch + "-reduced")
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(m, cfg, B, S, jax.random.PRNGKey(1))
    batch.pop("labels", None)
    cache = m.init_cache(B, S + 4, COOPT)
    logits, cache = m.prefill(p, batch, cache, COOPT)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = m.decode_step(p, {"token": tok}, cache, COOPT)
    assert logits2.shape == (B, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits2, np.float32)).any()
    # input_specs already folds the vlm patch prefix into S
    np.testing.assert_array_equal(np.asarray(cache["length"]), S + 1)


@pytest.mark.parametrize("arch", ALL_IDS)
def test_decode_consistency_with_forward(arch):
    """Greedy continuation via prefill+decode must match teacher forcing:
    decode logits at position t == forward logits at t (same tokens)."""
    cfg = get_config(arch + "-reduced")
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 1, 24
    key = jax.random.PRNGKey(5)
    batch = _batch(m, cfg, B, S + 1, key)
    batch.pop("labels", None)
    full_tokens = batch["tokens"]

    coopt = COOPT
    if cfg.num_experts:
        # capacity-MoE drops are S-dependent; dropless capacity
        # (cf >= E / top_k) makes teacher forcing == serving exactly
        coopt = COOPT.replace(
            moe_capacity_factor=float(cfg.num_experts) / cfg.top_k)

    fwd_logits, _ = m.forward(p, dict(batch), coopt)

    pre = dict(batch)
    pre["tokens"] = full_tokens[:, :-1]
    S_text = pre["tokens"].shape[1]
    cache = m.init_cache(B, S + 8, coopt)
    pl_logits, cache = m.prefill(p, pre, cache, coopt)
    # prefill last-token logits == forward logits at position S_text-1
    a = np.asarray(fwd_logits[:, S_text - 1], np.float32)
    b = np.asarray(pl_logits, np.float32)
    atol = 0.15 * max(np.abs(a).max(), 1.0)   # fp8 cache + bf16 skew
    np.testing.assert_allclose(a, b, atol=atol)

    # decode of the held-out token == forward logits at position S_text
    tok = full_tokens[:, -1:].astype(jnp.int32)
    de_logits, _ = m.decode_step(p, {"token": tok}, cache, coopt)
    a2 = np.asarray(fwd_logits[:, S_text], np.float32)
    b2 = np.asarray(de_logits, np.float32)
    np.testing.assert_allclose(a2, b2, atol=atol)
